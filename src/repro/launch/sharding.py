"""Sharding rules: logical axis names -> mesh axes, per architecture family.

The model code annotates every parameter (ParamSpec.axes) and the key
activations (``shard_act``) with *logical* names.  A rules table maps those
names onto mesh axes; strategies are data:

  * ``base_rules``     — TP on 'model', DP(+pod) on batch, FSDP off.
  * ``fsdp_rules``     — adds FSDP: 'embed' (the axis every weight matrix
    shares) is sharded over 'data', so param + optimizer-state memory scales
    1/(data*model).  XLA inserts the all-gather before use (prefetchable).
  * per-arch adjustments: MoE experts on 'model' (EP), kv_heads replicated
    when n_kv < model-axis size (MQA), SSM inner dim on 'model'.

``param_shardings(cfg, mesh, axes_tree, rules)`` maps a logical-axes pytree
to NamedShardings for pjit in_shardings.
"""
from __future__ import annotations

import collections
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import (
    Mesh, NamedSharding, PartitionSpec as P, SingleDeviceSharding,
)

from repro.meshctx import logical_to_spec
from repro.models.common import ModelConfig
from repro.obs.d2h import leaves_nbytes

__all__ = [
    "make_rules", "param_shardings", "batch_shardings", "data_axes",
    "local_lane_mesh", "lane_padded_capacity", "lane_spec", "lane_put",
    "HostStager", "pinned_host_sharding",
]


def data_axes(mesh: Mesh) -> tuple:
    """The mesh axes carrying the batch: ('pod','data') or ('data',)."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


# ---------------------------------------------------------------------------
# Event-serving lane sharding (DetectorPool): a 1-D 'lanes' mesh over the
# local devices.  Lane->device placement is pure data — lane i lives at a
# fixed offset of the stacked state pytree, so membership churn (join/leave)
# moves no arrays and triggers no recompiles; the detector step has no
# cross-lane term, so the sharded pool needs no collectives at all.
# ---------------------------------------------------------------------------


def local_lane_mesh(n_devices: Optional[int] = None) -> Mesh:
    """1-D ``('lanes',)`` mesh over the local devices (or the first
    ``n_devices`` of them).  A single-device host yields a 1-wide mesh, so
    sharded and unsharded pools share every code path."""
    import numpy as np

    devs = jax.local_devices()
    if n_devices is not None:
        devs = devs[: int(n_devices)]
    return Mesh(np.asarray(devs), ("lanes",))


def lane_padded_capacity(capacity: int, mesh: Mesh) -> int:
    """Physical lane count: ``capacity`` rounded up so the lane axis splits
    evenly across the mesh (the padding lanes just ride along masked)."""
    n = mesh.shape["lanes"]
    return ((int(capacity) + n - 1) // n) * n


def lane_spec(lane_axis: int = 0) -> P:
    """PartitionSpec placing ``lane_axis`` on the 'lanes' mesh axis (all
    other dims replicated; rank-deficient leaves — scalars next to a
    lane-stacked tree — should use ``P()`` instead)."""
    return P(*([None] * lane_axis), "lanes")


def lane_put(mesh: Mesh, tree, lane_axis: int = 0):
    """device_put a lane-stacked pytree with the lane axis sharded across
    the mesh; leaves with too few dims (shared scalars) stay replicated."""
    def one(leaf):
        spec = lane_spec(lane_axis) if leaf.ndim > lane_axis else P()
        return jax.device_put(leaf, NamedSharding(mesh, spec))

    return jax.tree.map(one, tree)


def pinned_host_sharding(device) -> Optional[SingleDeviceSharding]:
    """``pinned_host`` sharding for ``device``, or None when the runtime
    doesn't expose one (CPU devices, or backends without memory spaces).

    Factored out of ``HostStager`` so the capability probe is unit-testable
    with stub devices on CPU-only hosts.
    """
    if getattr(device, "platform", "cpu") == "cpu":
        return None
    try:
        kinds = {m.kind for m in device.addressable_memories()}
    except Exception:
        return None
    if "pinned_host" not in kinds:
        return None
    return SingleDeviceSharding(device, memory_kind="pinned_host")


class HostStager:
    """Pinned (page-locked) host staging for H2D event uploads.

    A plain ``jnp.asarray(host_array)`` upload gives the driver a pageable
    buffer, so every copy pays a hidden pageable -> pinned bounce and the
    DMA cannot overlap compute.  On runtimes that expose a ``pinned_host``
    memory space (CUDA, TPU) this stager device_puts the host array into
    pinned memory first and then issues the device copy from there — the
    second hop reads locked pages directly, making the pool's H2D event
    uploads async-copy-capable.  On hosts without a pinned space
    (CPU-only CI) ``put`` degrades transparently to ``jnp.asarray``: same
    values, same device, no staging — so every caller keeps one code path.

    ``depth`` sizes the in-flight double buffer: the stager keeps the last
    ``depth`` pinned slabs alive (a bounded deque), so a caller that stages
    upload *i+1* while upload *i*'s device copy is still in flight never
    races the source pages — depth 2 is the pump pipeline's stage-ahead
    window (one block staging while one executes).
    """

    def __init__(self, device=None, *, depth: int = 2):
        if depth < 1:
            raise ValueError("depth must be >= 1")
        self.device = jax.devices()[0] if device is None else device
        self._pinned = pinned_host_sharding(self.device)
        self.depth = int(depth)
        self._inflight = collections.deque(maxlen=self.depth)
        self.uploads = 0          # put() calls routed through this stager
        self.staged_bytes = 0     # bytes that went via pinned memory

    @property
    def pinned(self) -> bool:
        """True iff uploads actually stage through pinned host memory."""
        return self._pinned is not None

    def put(self, arr) -> jax.Array:
        self.uploads += 1
        if self._pinned is None:
            return jnp.asarray(arr)
        staged = jax.device_put(arr, self._pinned)
        # byte math lives in repro.obs (the CI metrics-ownership lint
        # bans ad-hoc nbytes arithmetic in serve/ and launch/)
        self.staged_bytes += leaves_nbytes(staged)
        # retain the pinned slab until `depth` newer uploads have staged:
        # the second-hop copy may still be reading these locked pages when
        # the caller moves on to stage the next block
        self._inflight.append(staged)
        return jax.device_put(staged, self.device)


def make_rules(cfg: ModelConfig, mesh: Mesh, *, fsdp: bool = True,
               global_batch: Optional[int] = None,
               overrides: Optional[dict] = None) -> dict:
    """Logical-axis -> mesh-axis rules for (cfg, mesh).

    ``global_batch``: when given, the batch axes shrink to the largest prefix
    of ('pod','data') whose product divides it (batch=1 long-context decode
    replicates the batch instead of failing to shard).
    """
    batch = data_axes(mesh)
    if global_batch is not None:
        chosen = []
        prod = 1
        for a in batch:
            if global_batch % (prod * mesh.shape[a]) == 0:
                chosen.append(a)
                prod *= mesh.shape[a]
        batch = tuple(chosen)
    model_size = mesh.shape.get("model", 1)

    rules: dict = {
        # --- activations ---------------------------------------------------
        "batch": batch,
        "seq": None,
        "vocab": "model",
        "heads": "model",
        "kv_heads": "model",
        "mlp": "model",
        "expert": "model",       # EP
        "expert_cap": batch,     # token groups stay data-sharded
        # --- params ----------------------------------------------------------
        "embed": "data" if fsdp else None,     # FSDP shard axis
        "embed2": "model",                     # concat-input projections (TP)
        "layers": None,
        "head_dim": None,
        "q_lora": None,
        "kv_lora": None,
        # SSM
        "inner": "model",
        "inner_all": "model",
        "ssm_heads": None,
    }

    # Experts take the model axis (EP); the expert FF dim then stays local.
    # If experts don't divide the axis, fall back to TP inside experts.
    rules["expert_mlp"] = None
    if cfg.n_experts and cfg.n_experts % model_size != 0:
        rules["expert"] = None
        rules["expert_mlp"] = "model"
    # MQA / small-KV: replicating KV heads beats padding a size-<16 axis.
    if 0 < cfg.n_kv < model_size:
        rules["kv_heads"] = None
    # Heads not divisible by the model axis (e.g. qwen2-0.5b's 14 heads):
    # GSPMD would pad; replicate instead and keep TP on the MLP only.
    if cfg.n_heads and cfg.n_heads % model_size != 0:
        rules["heads"] = None
    if cfg.vocab % model_size != 0:
        rules["vocab"] = None

    if overrides:
        rules.update(overrides)
    return rules


def param_shardings(mesh: Mesh, axes_tree, rules: dict):
    """Pytree of logical-axes tuples -> pytree of NamedShardings."""
    def one(axes):
        spec = logical_to_spec(axes, rules)
        return NamedSharding(mesh, spec)

    return jax.tree.map(
        one, axes_tree, is_leaf=lambda x: isinstance(x, tuple)
    )


def batch_shardings(mesh: Mesh, batch_tree, rules: dict):
    """Input batches: leading dim on the batch axes, rest replicated."""
    batch = rules.get("batch")

    def one(leaf):
        nd = len(leaf.shape)
        if nd == 0:
            return NamedSharding(mesh, P())
        return NamedSharding(mesh, P(batch, *([None] * (nd - 1))))

    return jax.tree.map(one, batch_tree)


def cache_shardings(mesh: Mesh, cache_tree, rules: dict, cfg: ModelConfig):
    """Decode caches: (layers/sites, batch, ...) -> batch on axis 1; the
    kv-head axis (if present and sharded) follows the rules.  ``enc_out``
    (whisper's encoder output) is the one un-stacked leaf: batch-first."""
    batch = rules.get("batch")

    def one(path, leaf):
        names = "/".join(str(getattr(k, "key", k)) for k in path)
        nd = len(leaf.shape)
        if "enc_out" in names:
            return NamedSharding(mesh, P(batch, *([None] * (nd - 1))))
        if nd >= 4 and cfg.n_kv and leaf.shape[-2] == cfg.n_kv:
            kv = rules.get("kv_heads")
            return NamedSharding(
                mesh, P(None, batch, *([None] * (nd - 4)), kv, None)
            )
        if nd >= 2:
            return NamedSharding(mesh, P(None, batch, *([None] * (nd - 2))))
        return NamedSharding(mesh, P())

    return jax.tree_util.tree_map_with_path(one, cache_tree)
