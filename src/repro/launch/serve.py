"""Batched serving driver: prefill-free greedy decode of a token batch.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b --smoke \
        --batch 4 --steps 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.launch import sharding as sh
from repro.launch.mesh import make_local_mesh
from repro.meshctx import use_mesh_rules
from repro.models import transformer as T
from repro.train.train_step import make_serve_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--steps", type=int, default=32)
    ap.add_argument("--cache-len", type=int, default=128)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--kv-quant", action="store_true",
                    help="int8 KV cache (attention families; §Perf lever)")
    args = ap.parse_args(argv)

    cfg = configs.get_smoke(args.arch) if args.smoke else configs.get(args.arch)
    if args.kv_quant:
        import dataclasses
        cfg = dataclasses.replace(cfg, kv_quant=True)
    mesh = make_local_mesh(data=len(jax.devices()))
    rules = sh.make_rules(cfg, mesh, global_batch=args.batch)

    params, _ = T.init_params(cfg, jax.random.PRNGKey(0))
    cache = T.zeros_cache(cfg, args.batch, args.cache_len)
    serve = make_serve_step(cfg, greedy=args.temperature == 0.0,
                            temperature=max(args.temperature, 1e-6))

    with use_mesh_rules(mesh, rules):
        step = jax.jit(serve)
        toks = jnp.asarray(
            np.random.default_rng(0).integers(1, cfg.vocab, (args.batch, 1)),
            jnp.int32)
        rng = jax.random.PRNGKey(1)
        seqs = [np.asarray(toks)[:, 0]]
        t0 = time.perf_counter()
        for pos in range(args.steps):
            rng, sub = jax.random.split(rng)
            toks, logits, cache = step(params, toks, cache, jnp.int32(pos), sub)
            seqs.append(np.asarray(toks)[:, 0])
        jax.block_until_ready(toks)
        dt = time.perf_counter() - t0

    seqs = np.stack(seqs, 1)
    print(f"decoded {args.steps} steps x batch {args.batch} in {dt:.2f}s "
          f"({args.steps * args.batch / dt:.1f} tok/s)")
    for b in range(min(args.batch, 4)):
        print(f"  seq[{b}]: {seqs[b, :16].tolist()}...")
    return seqs


if __name__ == "__main__":
    main()
