"""Launchers: production meshes, sharding rules, dry-run, roofline, drivers.

NOTE: ``repro.launch.dryrun`` must be run as __main__ in a fresh process —
it sets XLA_FLAGS (512 host devices) before importing jax.
"""
from repro.launch import mesh, roofline, sharding  # noqa: F401
