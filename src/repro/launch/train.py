"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b \
        --steps 200 --batch 8 --seq 256 --ckpt-dir /tmp/ckpt [--smoke]

Runs the full production loop on whatever devices exist: sharded params
(rules adapt to the local mesh), AdamW, deterministic synthetic LM data,
async checkpointing + crash-consistent resume, straggler monitoring
(repro.train.fault_tolerance.TrainSupervisor).
"""
from __future__ import annotations

import argparse
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.launch import sharding as sh
from repro.launch.mesh import make_local_mesh
from repro.meshctx import use_mesh_rules
from repro.models import transformer as T
from repro.train.fault_tolerance import TrainSupervisor
from repro.train.optimizer import AdamWConfig, adamw_init
from repro.train.train_step import make_train_step


def synthetic_batch_fn(cfg, batch, seq, *, seed=0):
    """Deterministic step->batch function (checkpoint-resume friendly):
    a bigram-ish random-walk language so the loss actually falls."""
    vocab = cfg.vocab

    def fn(step: int):
        rng = np.random.default_rng(seed + step)
        start = rng.integers(0, vocab, (batch, 1))
        steps = rng.integers(-3, 4, (batch, seq))
        toks = np.abs(start + np.cumsum(steps, 1)) % vocab
        b = {
            "tokens": jnp.asarray(toks, jnp.int32),
            "labels": jnp.asarray(np.roll(toks, -1, 1), jnp.int32),
            "mask": jnp.ones((batch, seq), jnp.float32),
        }
        if cfg.family == "vlm":
            b["img_embeds"] = jnp.zeros(
                (batch, cfg.n_img_tokens, cfg.d_model), cfg.act_dtype)
        if cfg.family == "encdec":
            b["frames"] = jnp.asarray(
                rng.normal(0, 1, (batch, cfg.n_audio_frames, cfg.d_model)),
                cfg.act_dtype)
        return b

    return fn


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config of the arch family")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--compress-grads", action="store_true")
    args = ap.parse_args(argv)

    cfg = configs.get_smoke(args.arch) if args.smoke else configs.get(args.arch)
    if cfg.family == "encdec":
        args.seq = min(args.seq, cfg.max_target_len)

    mesh = make_local_mesh(data=len(jax.devices()))
    rules = sh.make_rules(cfg, mesh, global_batch=args.batch)

    params, axes = T.init_params(cfg, jax.random.PRNGKey(0))
    opt_cfg = AdamWConfig(lr=args.lr, warmup_steps=min(20, args.steps // 10 + 1),
                          total_steps=args.steps)
    opt_state = adamw_init(params, opt_cfg)

    step_fn = make_train_step(cfg, opt_cfg, microbatches=args.microbatches,
                              compress_grads=args.compress_grads)

    def jit_step(params, opt_state, batch):
        with use_mesh_rules(mesh, rules):
            return jax.jit(step_fn)(params, opt_state, batch)

    losses = []

    def on_metrics(step, m):
        losses.append(m["loss"])
        if step % 10 == 0:
            print(f"step {step:5d}  loss {m['loss']:.4f}  "
                  f"gnorm {m.get('grad_norm', 0):.2f}  dt {m['dt']*1e3:.0f}ms",
                  flush=True)

    sup = TrainSupervisor(args.ckpt_dir, ckpt_every=args.ckpt_every)
    params, opt_state = sup.run(
        jit_step, params, opt_state,
        synthetic_batch_fn(cfg, args.batch, args.seq),
        args.steps, on_metrics=on_metrics,
    )
    if losses:
        k = max(len(losses) // 10, 1)
        print(f"first-{k} mean loss {np.mean(losses[:k]):.4f} -> "
              f"last-{k} mean {np.mean(losses[-k:]):.4f}")
        if sup.monitor.flagged:
            print(f"straggler steps flagged: {sup.monitor.flagged[:5]}")
    return params


if __name__ == "__main__":
    main()
