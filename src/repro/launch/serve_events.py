"""Event-camera serving driver: a DetectorPool under synthetic live traffic.

    PYTHONPATH=src python -m repro.launch.serve_events --sessions 4 \
        --duration-us 40000 --slab 400 --dvfs --ring-rounds 8 \
        --drain-mode async

Spins up a ``DetectorPool`` (ring-buffered K-round executor; lane-sharded
automatically when the host has >1 local device), connects ``--sessions``
synthetic cameras with staggered joins, feeds their streams in fixed-size
slabs round-robin, and reports aggregate throughput, per-slab latency
percentiles, and the ring runtime counters (host fetches per round,
buffered/dropped rounds, pump drain wait) — the serving-side counterpart of
``repro.launch.serve`` (LM decode driver).

``--drain-mode`` picks the readout runtime:

  * ``async`` (default): double-buffered device rings per bucket; a
    dedicated reader thread performs the blocking ``device_get`` while the
    pump keeps scanning rounds into the live ring.  The pump's only drain
    cost is the atomic ring swap (``pump_drain_wait_s`` stays near zero
    unless the reader falls behind the spare ring).
  * ``sync``: the PR 3 single-ring runtime — every drain blocks the pump
    thread on the fetch.  Kept for comparison and debugging; both modes are
    bit-exact (property-tested).

Backpressure is observable, not silent: every round the driver checks
``pool.pool_stats()`` and logs when the overflow policy dropped rounds
(``--overflow drop_oldest``) or when ring occupancy forced an early
drain/seal.
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro.core import pipeline
from repro.events import synthetic
from repro.serve import DetectorPool


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--sessions", type=int, default=4)
    ap.add_argument("--duration-us", type=int, default=40_000)
    ap.add_argument("--chunk", type=int, default=256)
    ap.add_argument("--slab", type=int, default=400,
                    help="events per arriving slab")
    ap.add_argument("--ring-rounds", type=int, default=8,
                    help="K: rounds per executor block / ring capacity")
    ap.add_argument("--overflow", default="drain",
                    choices=("drain", "drop_oldest"),
                    help="ring overflow policy (drain=lossless backpressure)")
    ap.add_argument("--drain-mode", default="async",
                    choices=("async", "sync"),
                    help="async: reader thread fetches sealed rings off the "
                         "pump thread; sync: drains block the caller")
    ap.add_argument("--dvfs", action="store_true",
                    help="online (in-step) DVFS instead of fixed 1.2 V")
    ap.add_argument("--backend", default="jnp",
                    choices=("jnp", "pallas_nmc", "pallas_batched"))
    args = ap.parse_args(argv)

    cfg = pipeline.PipelineConfig(
        chunk=args.chunk, lut_every_chunks=2, backend=args.backend,
        dvfs=args.dvfs, dvfs_online=args.dvfs,
    )
    streams = [
        synthetic.shapes_stream(duration_us=args.duration_us, seed=s)
        for s in range(args.sessions)
    ]
    pool = DetectorPool(cfg, capacity=args.sessions,
                        ring_rounds=args.ring_rounds,
                        on_overflow=args.overflow,
                        drain_mode=args.drain_mode)
    ps = pool.pool_stats()
    print(f"pool: capacity {args.sessions}, ring_rounds {args.ring_rounds} "
          f"({args.overflow}, drain_mode={args.drain_mode}), "
          f"sharded={ps['sharded']} over {ps['devices']} device(s)")

    # Warm both executor shapes (K-block + 1-round) outside the timed loop.
    pool.warmup(streams[0].xy, streams[0].ts)
    ps0 = pool.pool_stats()              # baselines: exclude warmup work
    drains0 = ps0["pump_forced_drains"]
    drain_wait0 = ps0["pump_drain_wait_s"]

    lanes, cursors = {}, {}
    lat_ms, done = [], 0
    dropped_seen = 0
    forced_drains = 0
    n_total = sum(len(s) for s in streams)
    t0 = time.perf_counter()
    while done < args.sessions:
        # staggered joins: one new camera per round until all are live
        if len(cursors) < args.sessions:
            i = len(cursors)
            lanes[i] = pool.connect(seed=i)
            cursors[i] = 0
        t1 = time.perf_counter()
        for i, lane in list(lanes.items()):
            st, c = streams[i], cursors[i]
            if c >= len(st):
                pool.flush(lane)
                pool.disconnect(lane)
                del lanes[i]
                done += 1
                continue
            pool.feed(lane, st.xy[c:c + args.slab], st.ts[c:c + args.slab])
            cursors[i] = c + args.slab
        # mid-pump makes-room events are counted by the pool itself
        # (host_fetches deltas are racy in async mode: the reader counts a
        # fetch when the transfer completes, not when the pump seals)
        drains_before = pool.pool_stats()["pump_forced_drains"]
        pool.pump()
        now = pool.pool_stats()["pump_forced_drains"]
        if now > drains_before:
            if forced_drains == 0:
                print("  [backpressure] ring full mid-pump: draining early "
                      "(lossless; fetch cadence rises under this load)")
            forced_drains = now - drains0
        for lane in lanes.values():
            pool.poll(lane)
        lat_ms.append((time.perf_counter() - t1) * 1e3)
        # backpressure: log drops instead of silently losing rounds
        ps = pool.pool_stats()
        if ps["dropped_rounds_total"] > dropped_seen:
            print(f"  [backpressure] ring dropped "
                  f"{ps['dropped_rounds_total'] - dropped_seen} round(s) "
                  f"(total {ps['dropped_rounds_total']}) — pollers lagging")
            dropped_seen = ps["dropped_rounds_total"]
    dt = time.perf_counter() - t0

    lat = np.asarray(lat_ms)
    ps = pool.pool_stats()
    print(f"served {args.sessions} sessions / {n_total} events in {dt:.2f}s "
          f"({n_total / dt / 1e3:.1f} kev/s aggregate)")
    print(f"round latency ms: p50 {np.percentile(lat, 50):.2f}  "
          f"p99 {np.percentile(lat, 99):.2f}  max {lat.max():.2f}")
    print(f"ring: {ps['rounds_executed']} rounds / {ps['host_fetches']} "
          f"host fetches "
          f"({ps['rounds_executed'] / max(ps['host_fetches'], 1):.1f} "
          f"rounds per blocking transfer), "
          f"{forced_drains} forced mid-pump drains, "
          f"{ps['dropped_rounds_total']} dropped")
    print(f"pump drain wait: "
          f"{(ps['pump_drain_wait_s'] - drain_wait0) * 1e3:.2f} ms total "
          f"({args.drain_mode}; async seals swap buffers instead of "
          f"fetching), reader lag {ps['reader_lag_rounds']} round(s)")
    print(f"compiled executors: {pool.compile_cache_sizes()} "
          f"(membership churn must not recompile)")
    pool.close()
    return dt, lat


if __name__ == "__main__":
    main()
