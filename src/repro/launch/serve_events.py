"""Event-camera serving driver: a DetectorPool under synthetic live traffic.

    PYTHONPATH=src python -m repro.launch.serve_events --sessions 4 \
        --duration-us 40000 --slab 400 --dvfs --ring-rounds 8 \
        --drain-mode async --policy adaptive --buckets 64,256,1024 \
        --connect-chunk 64

Spins up a ``DetectorPool`` (ring-buffered K-round executor; lane-sharded
automatically when the host has >1 local device), connects ``--sessions``
synthetic cameras with staggered joins, feeds their streams in fixed-size
slabs round-robin, and reports aggregate throughput, per-slab latency
percentiles, and the ring runtime counters (host fetches per round,
buffered/dropped rounds, pump drain wait) — the serving-side counterpart of
``repro.launch.serve`` (LM decode driver).

``--drain-mode`` picks the readout runtime:

  * ``async`` (default): an N-deep ring-of-rings per bucket
    (``--ring-depth``, default 2 = double buffering); a dedicated reader
    thread performs the blocking ``device_get`` while the pump keeps
    scanning rounds into the live ring.  The pump's only drain cost is the
    atomic ring swap (``pump_drain_wait_s`` stays near zero unless the
    reader falls behind every spare).
  * ``sync``: the PR 3 single-ring runtime — every drain blocks the pump
    thread on the fetch.  Kept for comparison and debugging; both modes are
    bit-exact (property-tested).

``--readout`` picks the D2H representation the drains fetch: ``dense``
(whole result slabs) or ``compact`` (packed kept-corner records — a
device-side stream-compaction pass shrinks each fetch by roughly
``chunk / cap``; per-slot overflow falls back to the dense row
losslessly, and ``pool_stats()`` reports the byte diet as
``d2h_bytes`` / ``d2h_bytes_saved``).  Results are bit-identical in
every combination of drain mode and readout.

``--policy`` picks the control plane:

  * ``static`` (default): PR 4 placement — each lane stays in the bucket
    chosen at connect (``--connect-chunk``, rounded up to a ``--buckets``
    tier) for life.
  * ``adaptive``: lanes whose measured events-per-half-window outgrow (or
    undershoot) their bucket for ``--migrate-patience`` consecutive drains
    are live-migrated to the better-fitting bucket (seal + drain +
    snapshot/restore; zero recompiles, bit-exact), and the most backlogged
    bucket pumps first.  Connect the sessions with a deliberately small
    ``--connect-chunk`` to watch them re-budget themselves upward.
  * ``ladder``: the overload ladder — every pump pass observes per-lane
    backlog pressure and, when it stays high, degrades lanes tier by tier
    (stretch LUT refresh -> lower the DVFS ceiling -> shed), lower QoS
    classes first (``--qos standard,premium``: premium lanes hold full
    quality throughout).  Try it with ``--burst-factor 2`` for the
    flash-crowd shape; watch the ``[ladder]`` log lines as the level
    climbs during the burst and recovers after it.  The ladder's bottom
    rung is placement: pinned at max level it packs sparse buckets' lanes
    together (below) and un-packs them home on full recovery.
  * ``pack``: fleet-wide lane packing alone — when the pool is paying H2D
    padding (uploaded slots exceed valid events), consolidate the lanes
    of sparsely-used buckets into the bucket where their traffic
    re-chunks cheapest, cutting padded upload bytes.  Same seal + drain +
    snapshot/restore migration as ``adaptive``; zero recompiles.

``--pipeline-depth`` sizes the pump's stage-ahead window: each pump pass
stages block *i+1* (host gather + pinned H2D upload) while block *i* runs
on device, and all of a pass's control-knob writes coalesce into one
batched jitted update.  Depth 1 is the serial pre-pipeline pump; every
depth is bit-exact (property-tested).  The final report prints the
overlap counters (``pump_stages_overlapped / pump_stages``) and how much
stage time landed while the device was busy.

Backpressure and migration are observable, not silent: every round the
driver checks ``pool.pool_stats()`` and logs dropped rounds (``--overflow
drop_oldest``), forced mid-pump drains, and each applied migration; the
final per-lane report prints the rate estimate, bucket, and migration
count ``stats()`` now carries.
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro import obs as obs_mod
from repro.core import pipeline
from repro.events import synthetic
from repro.serve import DetectorPool

# The console rendering of a metrics emission: the pipeline/coalescing/
# pack summary keys, rendered by a LogSink from the SAME record the JSONL
# trail gets — one emit, N sinks, no bespoke report block.
_SUMMARY_FIELDS = (
    "pump_stages", "pump_stage_s", "pump_stage_hidden_s",
    "pump_stage_overlap", "ctrl_batched_writes", "ctrl_actions_coalesced",
    "observation_rebuilds", "observation_reuses", "h2d_event_slots",
    "h2d_valid_events", "migrations_total",
)


def _attach_sinks(pool, metrics_out):
    """Wire the driver's sinks onto the pool registry: a console summary
    LogSink (always) plus a JSONL trail when ``--metrics-out`` is given,
    fanned out through one CompositeSink so a broken file sink can never
    take the console reporting down with it."""
    sinks = [obs_mod.LogSink(write=lambda s: print("  " + s),
                             fields=_SUMMARY_FIELDS)]
    jsonl = None
    if metrics_out:
        jsonl = obs_mod.JsonlSink(metrics_out)
        sinks.append(jsonl)
    composite = obs_mod.CompositeSink(sinks)
    pool.metrics.attach(composite)
    return jsonl


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--sessions", type=int, default=4)
    ap.add_argument("--duration-us", type=int, default=40_000)
    ap.add_argument("--chunk", type=int, default=256)
    ap.add_argument("--slab", type=int, default=400,
                    help="events per arriving slab")
    ap.add_argument("--ring-rounds", type=int, default=8,
                    help="K: rounds per executor block / ring capacity")
    ap.add_argument("--ring-depth", type=int, default=2,
                    help="device rings per bucket in async mode (2 = the "
                         "PR 4 double buffer; deeper absorbs longer fetch "
                         "stalls)")
    ap.add_argument("--overflow", default="drain",
                    choices=("drain", "drop_oldest"),
                    help="ring overflow policy (drain=lossless backpressure)")
    ap.add_argument("--drain-mode", default="async",
                    choices=("async", "sync"),
                    help="async: reader thread fetches sealed rings off the "
                         "pump thread; sync: drains block the caller")
    ap.add_argument("--readout", default="dense",
                    choices=("dense", "compact"),
                    help="ring readout representation: dense fetches whole "
                         "(rounds, lanes, chunk) result slabs; compact "
                         "fetches packed kept-corner records (~chunk/cap "
                         "fewer D2H bytes per drain, dense-row fallback on "
                         "overflow; results bit-identical either way)")
    ap.add_argument("--compact-cap", type=int, default=None,
                    help="kept-corner records per ring slot under "
                         "--readout compact (default: chunk // 8)")
    ap.add_argument("--policy", default="static",
                    choices=("static", "adaptive", "ladder", "pack"),
                    help="control plane: static=PR 4 placement for life; "
                         "adaptive=rate-aware live bucket migration; "
                         "ladder=QoS-ordered overload degradation "
                         "(observe->decide->actuate per pump pass); "
                         "pack=fleet-wide lane packing that migrates "
                         "sparse buckets' lanes together to minimize "
                         "padded H2D upload bytes")
    ap.add_argument("--pipeline-depth", type=int, default=2,
                    help="pump stage-ahead window: blocks staged (host "
                         "gather + H2D upload) while earlier blocks run "
                         "on device; 1 = the serial pump (bit-exact "
                         "either way)")
    ap.add_argument("--qos", default="standard",
                    help="comma-separated QoS classes assigned to sessions "
                         "round-robin (ladder policy: classes listed first "
                         "in the ladder config degrade first; e.g. "
                         "'standard,premium')")
    ap.add_argument("--burst-factor", type=float, default=None,
                    help="drive traffic with a flash-crowd burst_stream at "
                         "this overload factor instead of shapes_stream "
                         "(the ladder demo shape)")
    ap.add_argument("--buckets", default=None,
                    help="comma-separated chunk-size buckets "
                         "(e.g. 64,256,1024); default: just --chunk")
    ap.add_argument("--connect-chunk", type=int, default=None,
                    help="per-session chunk request at connect (rounded up "
                         "to a bucket); default: --chunk")
    ap.add_argument("--migrate-patience", type=int, default=3,
                    help="consecutive drains past the hysteresis threshold "
                         "before an adaptive migration commits")
    ap.add_argument("--metrics-out", default=None, metavar="PATH.jsonl",
                    help="append every metrics emission (periodic + final) "
                         "as one JSON record per line to this file")
    ap.add_argument("--metrics-interval", type=int, default=25,
                    help="serving rounds between periodic metrics "
                         "emissions (0 disables the periodic emits; the "
                         "final emission always happens)")
    ap.add_argument("--dvfs", action="store_true",
                    help="online (in-step) DVFS instead of fixed 1.2 V")
    ap.add_argument("--backend", default="jnp",
                    choices=("jnp", "pallas_nmc", "pallas_batched"))
    args = ap.parse_args(argv)

    cfg = pipeline.PipelineConfig(
        chunk=args.chunk, lut_every_chunks=2, backend=args.backend,
        dvfs=args.dvfs, dvfs_online=args.dvfs,
    )
    buckets = (
        tuple(int(b) for b in args.buckets.split(","))
        if args.buckets else None
    )
    if args.burst_factor is not None:
        half = cfg.dvfs_cfg.half_us
        n_win = max(4, args.duration_us // half)
        streams = [
            synthetic.burst_stream(
                2 * args.chunk, n_win, half,
                burst_factor=args.burst_factor, seed=s,
            )
            for s in range(args.sessions)
        ]
    else:
        streams = [
            synthetic.shapes_stream(duration_us=args.duration_us, seed=s)
            for s in range(args.sessions)
        ]
    qos_cycle = [q.strip() for q in args.qos.split(",") if q.strip()]
    pool = DetectorPool(cfg, capacity=args.sessions,
                        ring_rounds=args.ring_rounds,
                        ring_depth=args.ring_depth,
                        buckets=buckets,
                        on_overflow=args.overflow,
                        drain_mode=args.drain_mode,
                        readout=args.readout,
                        compact_cap=args.compact_cap,
                        policy=args.policy,
                        pipeline_depth=args.pipeline_depth,
                        migrate_patience=args.migrate_patience)
    ps = pool.pool_stats()
    print(f"pool: capacity {args.sessions}, ring_rounds {args.ring_rounds} "
          f"x depth {ps['ring_depth']} "
          f"({args.overflow}, drain_mode={args.drain_mode}, "
          f"readout={ps['readout']}, "
          f"policy={ps['policy']}, buckets={pool.buckets}), "
          f"sharded={ps['sharded']} over {ps['devices']} device(s)")

    # Warm both executor shapes (K-block + 1-round) outside the timed loop.
    pool.warmup(streams[0].xy, streams[0].ts)
    ps0 = pool.pool_stats()              # baselines: exclude warmup work
    drains0 = ps0["pump_forced_drains"]
    drain_wait0 = ps0["pump_drain_wait_s"]
    # sinks attach after warmup so the trail starts at the serving loop
    jsonl = _attach_sinks(pool, args.metrics_out)

    serve_rounds = 0
    lanes, cursors = {}, {}
    lat_ms, done = [], 0
    dropped_seen = 0
    drains_seen = drains0
    migrations_seen = 0
    ladder_level_seen = 0
    transitions_seen = 0
    final_lane_stats = []
    n_total = sum(len(s) for s in streams)
    t0 = time.perf_counter()
    while done < args.sessions:
        # staggered joins: one new camera per round until all are live
        if len(cursors) < args.sessions:
            i = len(cursors)
            lanes[i] = pool.connect(seed=i, chunk=args.connect_chunk,
                                    qos=qos_cycle[i % len(qos_cycle)])
            cursors[i] = 0
        # sample counters OUTSIDE the timed window: pool_stats walks every
        # lane and executor, and that observability cost must not inflate
        # the reported round latency percentiles
        drains_before = pool.pool_stats()["pump_forced_drains"]
        t1 = time.perf_counter()
        for i, lane in list(lanes.items()):
            st, c = streams[i], cursors[i]
            if c >= len(st):
                pool.flush(lane)
                final_lane_stats.append(pool.disconnect(lane))
                del lanes[i]
                done += 1
                continue
            pool.feed(lane, st.xy[c:c + args.slab], st.ts[c:c + args.slab])
            cursors[i] = c + args.slab
        pool.pump()
        for lane in lanes.values():
            pool.poll(lane)
        lat_ms.append((time.perf_counter() - t1) * 1e3)
        serve_rounds += 1
        if args.metrics_interval > 0 and \
                serve_rounds % args.metrics_interval == 0:
            pool.emit_metrics("periodic")
        ps = pool.pool_stats()
        # mid-pump makes-room events are counted by the pool itself
        # (host_fetches deltas are racy in async mode: the reader counts a
        # fetch when the transfer completes, not when the pump seals);
        # the delta here also covers drains forced inside flush()
        if ps["pump_forced_drains"] > drains_before:
            if drains_seen == drains0:
                print("  [backpressure] ring full mid-pump: draining early "
                      "(lossless; fetch cadence rises under this load)")
            drains_seen = ps["pump_forced_drains"]
        # migration: log each applied move (adaptive policy only)
        if ps["migrations_total"] > migrations_seen:
            print(f"  [migration] {ps['migrations_total'] - migrations_seen}"
                  f" lane(s) re-bucketed (total "
                  f"{ps['migrations_total']}; zero recompiles)")
            migrations_seen = ps["migrations_total"]
        # ladder: log level moves and actuated tier transitions
        lvl = ps.get("ladder_level", 0)
        if lvl != ladder_level_seen:
            word = "climbed" if lvl > ladder_level_seen else "descended"
            print(f"  [ladder] level {word} {ladder_level_seen} -> {lvl} "
                  f"(max {ps['ladder_max_level']}; degrade quality, "
                  f"never latency)")
            ladder_level_seen = lvl
        if ps.get("ladder_transitions", 0) > transitions_seen:
            print(f"  [ladder] {ps['ladder_transitions'] - transitions_seen}"
                  f" lane tier transition(s) actuated (total "
                  f"{ps['ladder_transitions']}; knob writes, no recompile)")
            transitions_seen = ps["ladder_transitions"]
        # backpressure: log drops instead of silently losing rounds
        if ps["dropped_rounds_total"] > dropped_seen:
            print(f"  [backpressure] ring dropped "
                  f"{ps['dropped_rounds_total'] - dropped_seen} round(s) "
                  f"(total {ps['dropped_rounds_total']}) — pollers lagging")
            dropped_seen = ps["dropped_rounds_total"]
    dt = time.perf_counter() - t0

    lat = np.asarray(lat_ms)
    ps = pool.pool_stats()
    forced_drains = ps["pump_forced_drains"] - drains0
    print(f"served {args.sessions} sessions / {n_total} events in {dt:.2f}s "
          f"({n_total / dt / 1e3:.1f} kev/s aggregate)")
    print(f"round latency ms: p50 {np.percentile(lat, 50):.2f}  "
          f"p99 {np.percentile(lat, 99):.2f}  max {lat.max():.2f}")
    print(f"ring: {ps['rounds_executed']} rounds / {ps['host_fetches']} "
          f"host fetches "
          f"({ps['rounds_executed'] / max(ps['host_fetches'], 1):.1f} "
          f"rounds per blocking transfer), "
          f"{forced_drains} forced mid-pump drains, "
          f"{ps['dropped_rounds_total']} dropped")
    print(f"pump drain wait: "
          f"{(ps['pump_drain_wait_s'] - drain_wait0) * 1e3:.2f} ms total "
          f"({args.drain_mode}; async seals swap buffers instead of "
          f"fetching), reader lag {ps['reader_lag_rounds']} round(s)")
    d2h = ps["d2h_bytes"]
    print(f"d2h readout ({ps['readout']}): {d2h / 1e6:.3f} MB fetched over "
          f"{ps['host_fetches']} fetch(es), "
          f"{ps['d2h_bytes_saved'] / 1e6:.3f} MB saved vs dense, "
          f"{ps['d2h_compact_overflow_slots']} overflow slot(s) "
          f"fell back to dense rows")
    pad = ps["h2d_padding_bytes"]
    print(f"h2d padding: {pad / 1e6:.3f} MB over "
          f"{ps['h2d_event_slots']} uploaded slots "
          f"({ps['h2d_valid_events']} valid events) — "
          f"{ps['migrations_total']} migration(s), policy={ps['policy']}")
    # pipeline/coalescing/pack summary: one registry emission rendered by
    # the attached sinks (console LogSink + optional JSONL trail) — the
    # record is the report, scheduler counters ride in record["scheduler"]
    print(f"pump pipeline (depth {ps['pipeline_depth']}) final emission:")
    pool.emit_metrics("final")
    if args.policy == "ladder":
        print(f"ladder: level {ps['ladder_level']}/{ps['ladder_max_level']} "
              f"at exit, {ps['ladder_transitions']} tier transition(s), "
              f"{ps['shed_events_total']} event(s) shed")
    for st in final_lane_stats:
        print(f"  lane {st['lane']}: bucket {st['bucket']}, "
              f"qos {st['qos']} (tier {st['ladder_tier']}), "
              f"rate est {st['events_per_s_est'] / 1e3:.1f} kev/s "
              f"(device est {st['device_events_per_s_est'] / 1e3:.1f}), "
              f"{st['migrations']} migration(s) {st['migration_log']}")
    print(f"compiled executors: {pool.compile_cache_sizes()} "
          f"(membership churn and migration must not recompile)")
    if jsonl is not None:
        jsonl.close()
        print(f"metrics trail: {args.metrics_out}")
    pool.close()
    return dt, lat


if __name__ == "__main__":
    main()
