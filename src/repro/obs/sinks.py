"""Metric sinks: where registry emissions go.

A sink is anything with ``emit(record: dict)`` (and optionally
``close()``).  ``MetricsRegistry.emit()`` builds one record per call —
``{"kind", "namespace", "t_wall", "metrics": {key: value}}`` — and fans it
out to every attached sink.  The composite-tracker idiom: the registry
never knows whether it is talking to a console, a JSONL file, a
Prometheus text file, or all three at once, and one broken sink never
poisons the others (``CompositeSink`` isolates per-sink faults).

- ``LogSink``    — human-oriented one-liners through a callable
                   (``print`` by default, or a logger method).
- ``JsonlSink``  — one JSON object per line, append-only, thread-safe;
                   the machine-readable trail ``serve_events
                   --metrics-out`` writes.
- ``PromSink``   — Prometheus text exposition (version 0.0.4) rewritten
                   atomically on every emit; a node-exporter-style
                   textfile, scrapeable without a server (the scrape
                   *endpoint* lives with the future ingest tier).
- ``CompositeSink`` — fan-out with fault isolation.
"""
from __future__ import annotations

import io
import json
import os
import threading
from typing import Callable, Optional

__all__ = ["LogSink", "JsonlSink", "PromSink", "CompositeSink"]


def _json_default(o):
    # numpy scalars/arrays sneak into records via device math; coerce
    # without importing numpy here (obs must not depend on it)
    for attr in ("item",):
        f = getattr(o, attr, None)
        if callable(f):
            return f()
    tolist = getattr(o, "tolist", None)
    if callable(tolist):
        return tolist()
    raise TypeError(f"not JSON serializable: {type(o).__name__}")


class LogSink:
    """Render each record as one compact human-readable line.

    ``write`` is any ``str -> None`` callable (``print``,
    ``logger.info``, a list's ``append`` in tests).  ``fields`` limits
    the rendered metrics to keys containing any of the given substrings
    (a console summary wants 10 numbers, not 80).
    """

    def __init__(self, write: Callable[[str], None] = print,
                 fields: Optional[tuple] = None):
        self._write = write
        self._fields = tuple(fields) if fields else None

    def emit(self, record: dict) -> None:
        metrics = record.get("metrics", {})
        if self._fields is not None:
            metrics = {k: v for k, v in metrics.items()
                       if any(f in k for f in self._fields)}
        parts = []
        for k, v in metrics.items():
            if isinstance(v, float):
                parts.append(f"{k}={v:.6g}")
            else:
                parts.append(f"{k}={v}")
        ns = record.get("namespace", "")
        kind = record.get("kind", "snapshot")
        self._write(f"[{ns}:{kind}] " + " ".join(parts))


class JsonlSink:
    """Append one JSON object per emit to a file, thread-safe.

    Writes are serialized under a lock and flushed per record, so the
    pump thread, the reader thread, and a periodic monitor can all emit
    concurrently and a crash loses at most the in-flight line.  Records
    round-trip: ``read_jsonl(path)`` returns exactly what was emitted.
    """

    def __init__(self, path):
        self.path = os.fspath(path)
        self._lock = threading.Lock()
        parent = os.path.dirname(self.path)
        if parent:
            os.makedirs(parent, exist_ok=True)
        self._fh = open(self.path, "a", encoding="utf-8")

    def emit(self, record: dict) -> None:
        line = json.dumps(record, sort_keys=True, default=_json_default)
        with self._lock:
            if self._fh is None:
                return
            self._fh.write(line + "\n")
            self._fh.flush()

    def close(self) -> None:
        with self._lock:
            if self._fh is not None:
                self._fh.close()
                self._fh = None


def read_jsonl(path) -> list:
    """Load a JsonlSink trail back into a list of records."""
    out = []
    with open(os.fspath(path), encoding="utf-8") as fh:
        for line in fh:
            line = line.strip()
            if line:
                out.append(json.loads(line))
    return out


class PromSink:
    """Prometheus text exposition written to a file on every emit.

    The whole exposition is rewritten from the registry's current state
    (records are cumulative, so last-write-wins is correct) and swapped
    in atomically via tmp+rename — a scraper never sees a torn file.
    Needs the registry itself (for ``describe()`` HELP/TYPE lines and
    structured label access), so attach it via ``PromSink(path,
    registry)`` rather than relying on the flat record alone.
    """

    def __init__(self, path, registry):
        self.path = os.fspath(path)
        self._registry = registry
        self._lock = threading.Lock()
        parent = os.path.dirname(self.path)
        if parent:
            os.makedirs(parent, exist_ok=True)

    @staticmethod
    def _escape(s: str) -> str:
        return (str(s).replace("\\", r"\\").replace("\n", r"\n")
                .replace('"', r'\"'))

    @staticmethod
    def _fmt(v) -> str:
        if isinstance(v, bool):
            return "1" if v else "0"
        if isinstance(v, (int,)):
            return str(v)
        try:
            return repr(float(v))
        except (TypeError, ValueError):
            return "0"

    def render(self) -> str:
        """The full exposition for the current registry state."""
        reg = self._registry
        ns = reg.namespace or "repro"
        buf = io.StringIO()
        for m in reg.metrics():
            full = f"{ns}_{m.name}"
            buf.write(f"# HELP {full} {self._escape(m.desc)}\n")
            buf.write(f"# TYPE {full} {m.kind}\n")
            for key, h in m.samples():
                lbl = ""
                if m.labelnames:
                    pairs = ",".join(
                        f'{n}="{self._escape(v)}"'
                        for n, v in zip(m.labelnames, key))
                    lbl = "{" + pairs + "}"
                if m.kind == "histogram":
                    acc = 0
                    for bound, c in zip(m.buckets, h.bucket_counts):
                        acc += c
                        le = ('{le="%s"%s}'
                              % (repr(float(bound)),
                                 "," + lbl[1:-1] if lbl else ""))
                        buf.write(f"{full}_bucket{le} {acc}\n")
                    inf = ('{le="+Inf"%s}'
                           % ("," + lbl[1:-1] if lbl else ""))
                    buf.write(f"{full}_bucket{inf} {h.count}\n")
                    buf.write(f"{full}_sum{lbl} {self._fmt(h.sum)}\n")
                    buf.write(f"{full}_count{lbl} {h.count}\n")
                else:
                    buf.write(f"{full}{lbl} {self._fmt(h.value())}\n")
        return buf.getvalue()

    def emit(self, record: dict) -> None:
        text = self.render()
        with self._lock:
            tmp = self.path + ".tmp"
            with open(tmp, "w", encoding="utf-8") as fh:
                fh.write(text)
            os.replace(tmp, self.path)


class CompositeSink:
    """Fan one emit out to many sinks; one failing sink never poisons
    the rest (its first error is remembered in ``errors`` for tests and
    reported once through ``on_error``, default silent)."""

    def __init__(self, sinks, on_error: Optional[Callable] = None):
        self._sinks = list(sinks)
        self._on_error = on_error
        self._lock = threading.Lock()
        self.errors: dict[int, str] = {}

    def emit(self, record: dict) -> None:
        for i, sink in enumerate(self._sinks):
            try:
                sink.emit(record)
            except Exception as e:  # noqa: BLE001 — isolation is the point
                with self._lock:
                    first = i not in self.errors
                    if first:
                        self.errors[i] = f"{type(e).__name__}: {e}"
                if first and self._on_error is not None:
                    self._on_error(sink, e)

    def close(self) -> None:
        for sink in self._sinks:
            close = getattr(sink, "close", None)
            if close is None:
                continue
            try:
                close()
            except Exception:  # noqa: BLE001
                pass
