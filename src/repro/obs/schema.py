"""One source of truth for the serving stats surface.

Every key that ``PoolRuntime.stats()``, ``PoolRuntime.pool_stats()`` and
``StreamingDetector.stats()`` export is declared here with a one-line
description.  Three consumers render from this table and nothing else:

1. the pool's ``MetricsRegistry`` — registry metric descriptions are
   looked up here at declaration time;
2. the generated stats-key reference table appended to
   ``repro.serve.__doc__`` (``stats_reference_table()``);
3. the golden-key tests — they assert the *exported* key sets equal the
   *declared* ones, so a stat can't ship undocumented and a doc row
   can't outlive its stat.

Keys marked in ``WALL_TIME_KEYS`` are wall-clock witnesses: real and
exported, but excluded from byte-equality replay comparisons because two
runs of the same replay legitimately measure different walls.
"""
from __future__ import annotations

__all__ = [
    "LANE_STATS",
    "POOL_STATS",
    "POOL_BUCKET_STATS",
    "POLICY_STATS",
    "SESSION_STATS",
    "WALL_TIME_KEYS",
    "stats_reference_table",
]

# -- per-lane stats: DetectorPool.stats(lane) --------------------------------

LANE_STATS = {
    "lane": "lane index within the pool",
    "bucket": "chunk-size bucket the lane currently executes in",
    "n_events": "events accepted from this lane (pre-shed)",
    "n_chunks": "chunk rounds executed for this lane",
    "kept_total": "host-confirmed corner-kept events",
    "energy_pj": "host-confirmed modeled energy (pJ)",
    "latency_ns_per_event": "modeled ns/event over scored chunks",
    "buffered": "events parked in the host re-chunk buffer",
    "events_per_s_est": "paper 3-counter rate estimate (events/s)",
    "device_events_per_s_est": "device-confirmed rate estimate (events/s)",
    "migrations": "bucket migrations this lane completed",
    "migration_log": "list of (from_bucket, to_bucket) per migration",
    "migration_staged": "True while a migration is staged, not applied",
    "ring_capacity": "rounds per on-device result ring",
    "ring_rounds_buffered": "rounds in the lane's live (unsealed) ring",
    "ring_sealed_rounds": "rounds sealed to the reader, not yet drained",
    "ring_dropped_rounds": "rounds lost to overflow (confirmed+predicted)",
    "backlog_rounds": "full rounds waiting in the host buffer",
    "reader_lag_rounds": "sealed rounds the reader has not drained yet",
    "last_drain_wait_s": "wall seconds of this bucket's last forced drain",
    "qos": "lane quality-of-service class (ladder ordering)",
    "ladder_tier": "current degradation tier (0 = full quality)",
    "ctrl_lut_every": "effective LUT refresh interval knob",
    "ctrl_vdd_cap": "effective DVFS operating-point ceiling knob",
    "ctrl_shed": "True when the shed knob is engaged",
    "shed_events": "events dropped by shedding for this lane",
    "device_kept_total": "kept events incl. undrained device rounds",
    "device_energy_pj": "energy (pJ) incl. undrained device rounds",
    "device_latency_ns": "modeled ns/event incl. undrained rounds",
}

# -- pool-wide stats: DetectorPool.pool_stats() ------------------------------

POOL_STATS = {
    "capacity": "max concurrent lanes",
    "active": "currently connected lanes",
    "sharded": "True when lanes are sharded across local devices",
    "devices": "device count backing the lane mesh",
    "ring_rounds": "rounds per ring (ring capacity)",
    "ring_depth": "rings per bucket (ring-of-rings depth)",
    "pipeline_depth": "pump stage-ahead depth (1 = serial pump)",
    "on_overflow": "ring overflow policy (drop_oldest | drain)",
    "drain_mode": "reader drain mode (sync | async)",
    "readout": "ring readout representation (dense | compact)",
    "policy": "scheduler policy name",
    "host_fetches": "blocking device->host result transfers",
    "rounds_executed": "chunk rounds dispatched to executors",
    "pump_drain_wait_s": "wall seconds the pump spent waiting on drains",
    "pump_forced_drains": "mid-pump makes-room drain events",
    "pump_stages": "event-slab blocks staged for upload",
    "pump_stages_overlapped": "blocks staged while device compute ran",
    "pump_stage_overlap_ratio": "pump_stages_overlapped / pump_stages",
    "pump_stage_s": "wall seconds spent gathering/pinning/uploading",
    "pump_stage_hidden_s": "stage seconds hidden under device compute",
    "ctrl_batched_writes": "coalesced control-leaf batch updates",
    "ctrl_actions_coalesced": "knob actions folded into those batches",
    "observation_rebuilds": "LaneObservations built fresh",
    "observation_reuses": "LaneObservations served from generation cache",
    "reader_lag_rounds": "sealed-not-drained rounds across buckets",
    "migrations_total": "lane bucket migrations applied",
    "migrations_staged": "migrations staged for the next pump pass",
    "h2d_event_slots": "uploaded chunk slots including padding",
    "h2d_valid_events": "valid events inside those slots",
    "h2d_padding_bytes": "upload bytes spent on padding slots",
    "h2d_pinned_staging": "True when uploads stage via pinned host memory",
    "h2d_staged_uploads": "uploads that went through the pinned stager",
    "d2h_bytes": "result bytes fetched device->host across drains",
    "d2h_bytes_saved": "dense-equivalent bytes the compact readout skipped",
    "d2h_compact_overflow_slots": "slot-lanes that fell back to dense rows",
    "dropped_rounds_total": "rounds lost to overflow (confirmed+predicted)",
    "dropped_rounds_confirmed": "overflow drops confirmed by fetches",
    "shed_events_total": "shed events across currently-connected lanes",
    "buckets": "per-bucket sub-table (see bucket keys)",
}

# -- per-bucket sub-table: pool_stats()["buckets"][b] ------------------------

POOL_BUCKET_STATS = {
    "lanes": "lanes currently homed in this bucket",
    "events_per_s_est": "summed lane rate estimates (events/s)",
    "ring_rounds_buffered": "rounds in this bucket's live ring",
    "ring_sealed_rounds": "rounds sealed to the reader, undrained",
    "ring_dropped_rounds": "overflow drops (confirmed+predicted)",
    "h2d_event_slots": "uploaded chunk slots including padding",
    "h2d_valid_events": "valid events inside those slots",
    "executables": "compiled executor count {block, single} (<=1 each)",
}

# -- policy-dependent extras merged into pool_stats() ------------------------

POLICY_STATS = {
    "pack_moves": "pack/un-pack migrations emitted (pack, ladder)",
    "pack_saved_slots": "padded slots saved by packing (pack)",
    "ladder_level": "current fleet degradation level (ladder)",
    "ladder_max_level": "deepest level reached (ladder)",
    "ladder_transitions": "level transitions, both directions (ladder)",
}

# -- single-session stats: StreamingDetector.stats() -------------------------

SESSION_STATS = {
    "n_events": "events accepted this session",
    "n_chunks": "chunk rounds executed",
    "chunk": "current chunk size",
    "rebuckets": "live chunk-size changes",
    "kept_total": "host-confirmed corner-kept events",
    "energy_pj": "host-confirmed modeled energy (pJ)",
    "latency_ns_per_event": "modeled ns/event over scored chunks",
    "buffered": "events parked in the re-chunk buffer",
    "events_per_s_est": "paper 3-counter rate estimate (events/s)",
    "device_kept_total": "kept events incl. undrained device work",
    "device_energy_pj": "energy (pJ) incl. undrained device work",
    "device_latency_ns": "modeled ns/event incl. undrained work",
}

# Wall-clock witnesses: exported, but never byte-compared across replays.
WALL_TIME_KEYS = frozenset({
    "last_drain_wait_s",
    "pump_drain_wait_s",
    "pump_stage_s",
    "pump_stage_hidden_s",
})


def describe(table: str, key: str) -> str:
    """Description for ``key`` in one of the tables above (KeyError if
    the key is undeclared — declaration here is mandatory)."""
    return {
        "lane": LANE_STATS,
        "pool": POOL_STATS,
        "bucket": POOL_BUCKET_STATS,
        "policy": POLICY_STATS,
        "session": SESSION_STATS,
    }[table][key]


def stats_reference_table() -> str:
    """Render the stats-key reference appended to ``repro.serve.__doc__``.

    Generated, not hand-written: edits belong in the tables above.
    """
    sections = (
        ("stats(lane) — per-lane", LANE_STATS),
        ("pool_stats() — pool-wide", POOL_STATS),
        ("pool_stats()['buckets'][b] — per-bucket", POOL_BUCKET_STATS),
        ("pool_stats() policy extras", POLICY_STATS),
        ("StreamingDetector.stats() — per-session", SESSION_STATS),
    )
    lines = [
        "Stats-key reference (generated from repro.obs.schema — do not",
        "hand-edit; keys suffixed * are wall-clock witnesses excluded",
        "from byte-equality replay comparisons):",
        "",
    ]
    for title, table in sections:
        lines.append(title)
        width = max(len(k) for k in table) + 1
        for key, desc in table.items():
            star = "*" if key in WALL_TIME_KEYS else ""
            lines.append(f"  {key + star:<{width}} {desc}")
        lines.append("")
    return "\n".join(lines)
