"""Observability spine: metrics registry, sinks, and the stats schema.

``repro.obs`` is the single write path for serving witnesses.  The
runtime and scheduler mutate registry handles (``metrics``); attachable
sinks (``sinks``) fan emissions out to logs / JSONL / Prometheus text;
``schema`` declares every exported stats key with its description and is
the one source of truth for docs, registry metric HELP text, and the
golden-key tests.
"""
from repro.obs.metrics import (  # noqa: F401
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    default_registry,
    timer,
)
from repro.obs.sinks import (  # noqa: F401
    CompositeSink,
    JsonlSink,
    LogSink,
    PromSink,
    read_jsonl,
)
from repro.obs import schema  # noqa: F401
from repro.obs.d2h import leaves_nbytes  # noqa: F401

__all__ = [
    "leaves_nbytes",
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "default_registry",
    "timer",
    "CompositeSink",
    "JsonlSink",
    "LogSink",
    "PromSink",
    "read_jsonl",
    "schema",
]
