"""D2H transfer accounting helpers — the ONE place readout byte math lives.

The H2D side already has a single owner (the pinned-host stager's
slot/valid counters); this module is its D2H mirror: the runtime's fetch
paths call ``leaves_nbytes`` on exactly the leaves they hand to
``device_get`` and increment registry handles with the result, and the CI
metrics-ownership lint bans ad-hoc ``nbytes`` arithmetic in
``src/repro/serve`` / ``src/repro/launch`` so the accounting can never
fork.  ``nbytes`` is shape/dtype metadata on both device and host arrays,
so nothing here forces a device sync.
"""
from __future__ import annotations

__all__ = ["leaves_nbytes"]


def leaves_nbytes(*arrays) -> int:
    """Total payload bytes of the given arrays (device or host, or
    iterables of either; ``None`` entries are skipped).

    The fetch paths pass exactly what they hand to ``device_get``, so the
    counter reports what actually crossed (or, for the dense-equivalent
    baseline, would have crossed) the transfer — honest bytes on both
    readouts.
    """
    total = 0
    for a in arrays:
        if a is None:
            continue
        if hasattr(a, "nbytes"):
            total += int(a.nbytes)
        else:
            total += leaves_nbytes(*a)
    return total
