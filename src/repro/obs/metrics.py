"""Metrics registry: typed counters/gauges/histograms with labels.

One ``MetricsRegistry`` owns every metric of a subsystem (the process-wide
default lives in ``default_registry()``; each ``DetectorPool`` scopes its
own instance so two pools never collide on a counter).  A metric is
declared once — name, one-line description, label names — and mutated only
through the handles the registry hands out:

    reg = MetricsRegistry(namespace="pool")
    fetches = reg.counter("host_fetches", "blocking result transfers")
    slots = reg.counter("h2d_event_slots", "uploaded chunk slots",
                        labels=("bucket",))
    fetches.inc()
    slots.labels(bucket=256).inc(2048)

Handles are cheap bound objects (one attribute add under a per-metric
lock), so hot paths hold them directly instead of re-resolving labels.
The registry is the SINGLE write path for serving witnesses: the
byte-compatible ``stats()``/``pool_stats()`` exports read handle values,
they never own counters of their own (a CI grep bans the legacy bare-dict
spellings outside this package).

Descriptions are load-bearing, not decoration: ``describe()`` feeds the
Prometheus ``# HELP`` lines and the generated stats-key reference table in
``repro.serve.__doc__`` — one source of truth (``repro.obs.schema``).

``timer()`` is the one wall-clock everything observes through
(``time.perf_counter`` — monotonic, so a sink swap or an NTP step can
never change what a drain-wait witness measures).
"""
from __future__ import annotations

import bisect
import threading
import time
from typing import Optional

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "default_registry",
    "timer",
]

# Default histogram bucket bounds (seconds-flavored; callers override).
DEFAULT_BUCKETS = (
    0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1, 0.25, 0.5, 1.0, 2.5, 5.0,
)


def timer() -> float:
    """The wall clock every serving witness reads: ``time.perf_counter``.

    Monotonic and high-resolution.  Intervals are differences of two
    ``timer()`` reads — never ``time.time()`` (steps under NTP) and never
    a mix of clocks (the pre-registry timing hazard this helper retires).
    """
    return time.perf_counter()


def _label_key(labelnames: tuple, labels: dict) -> tuple:
    if set(labels) != set(labelnames):
        raise ValueError(
            f"expected labels {labelnames}, got {tuple(sorted(labels))}"
        )
    return tuple(str(labels[n]) for n in labelnames)


class _Handle:
    """A metric bound to one label combination: the object hot paths hold.

    Mutations take the parent metric's lock (shared across this metric's
    handles) — cheap, and safe from the pump, reader, and monitor threads
    at once.  ``value()`` reads without the lock: Python attribute reads
    of ints/floats are atomic, and every exported witness is either read
    under the pool lock or tolerant of a one-update-stale view.
    """

    __slots__ = ("_metric", "_key", "_v")

    def __init__(self, metric: "_Metric", key: tuple):
        self._metric = metric
        self._key = key
        self._v = 0

    def value(self):
        return self._v

    @property
    def labels(self) -> dict:
        return dict(zip(self._metric.labelnames, self._key))


class _CounterHandle(_Handle):
    def inc(self, n=1) -> None:
        if n < 0:
            raise ValueError(f"counter {self._metric.name} cannot decrease")
        with self._metric._lock:
            self._v += n


class _GaugeHandle(_Handle):
    def set(self, v) -> None:
        with self._metric._lock:
            self._v = v

    def add(self, n) -> None:
        with self._metric._lock:
            self._v += n


class _HistogramHandle(_Handle):
    __slots__ = ("count", "sum", "bucket_counts", "_samples")

    def __init__(self, metric: "_Metric", key: tuple):
        super().__init__(metric, key)
        self.count = 0
        self.sum = 0.0
        self.bucket_counts = [0] * (len(metric.buckets) + 1)
        # bounded raw-sample reservoir (keep-first): enough for the SLO
        # percentiles the scenario suite reads; the cumulative bucket
        # counts stay exact regardless
        self._samples: list = []

    def observe(self, v) -> None:
        m = self._metric
        with m._lock:
            self.count += 1
            self.sum += v
            self.bucket_counts[bisect.bisect_left(m.buckets, v)] += 1
            if len(self._samples) < m.max_samples:
                self._samples.append(float(v))

    def value(self):
        """Histograms export their count as the scalar value."""
        return self.count

    def percentile(self, q: float) -> float:
        """Percentile over the raw-sample reservoir (0 when empty)."""
        with self._metric._lock:
            s = sorted(self._samples)
        if not s:
            return 0.0
        i = (len(s) - 1) * min(max(q, 0.0), 100.0) / 100.0
        lo, hi = int(i), min(int(i) + 1, len(s) - 1)
        return s[lo] + (s[hi] - s[lo]) * (i - lo)


class _Metric:
    """Shared metric core: name, kind, description, label names, and the
    handle table.  Label-less metrics ARE their own (single) handle —
    ``counter.inc()`` works without a ``labels()`` hop."""

    kind = "untyped"
    _handle_cls = _Handle

    def __init__(self, name: str, desc: str, labelnames: tuple = (),
                 **kw):
        self.name = name
        self.desc = desc
        self.labelnames = tuple(str(n) for n in labelnames)
        self._lock = threading.Lock()
        self._handles: dict[tuple, _Handle] = {}
        self._default: Optional[_Handle] = None
        if not self.labelnames:
            self._default = self._handle_cls(self, ())
            self._handles[()] = self._default

    def labels(self, **labels) -> _Handle:
        """The handle for one label combination (created on first use)."""
        key = _label_key(self.labelnames, labels)
        with self._lock:
            h = self._handles.get(key)
            if h is None:
                h = self._handle_cls(self, key)
                self._handles[key] = h
            return h

    def samples(self) -> list:
        """``(label_values_tuple, handle)`` pairs, insertion order."""
        with self._lock:
            return list(self._handles.items())

    # label-less convenience: the metric IS its default handle
    def _need_default(self) -> _Handle:
        if self._default is None:
            raise ValueError(
                f"metric {self.name} has labels {self.labelnames}; "
                f"use .labels(...)"
            )
        return self._default

    def value(self):
        return self._need_default().value()


class Counter(_Metric):
    """Monotonically non-decreasing count (int or float increments)."""

    kind = "counter"
    _handle_cls = _CounterHandle

    def inc(self, n=1) -> None:
        self._need_default().inc(n)


class Gauge(_Metric):
    """A value that can go up and down (``set``/``add``)."""

    kind = "gauge"
    _handle_cls = _GaugeHandle

    def set(self, v) -> None:
        self._need_default().set(v)

    def add(self, n) -> None:
        self._need_default().add(n)


class Histogram(_Metric):
    """Distribution: exact cumulative bucket counts + count/sum, plus a
    bounded raw-sample reservoir for host-side percentiles."""

    kind = "histogram"
    _handle_cls = _HistogramHandle

    def __init__(self, name, desc, labelnames=(), *,
                 buckets=DEFAULT_BUCKETS, max_samples=8192):
        self.buckets = tuple(sorted(float(b) for b in buckets))
        self.max_samples = int(max_samples)
        super().__init__(name, desc, labelnames)

    def observe(self, v) -> None:
        self._need_default().observe(v)

    def percentile(self, q: float) -> float:
        return self._need_default().percentile(q)


class MetricsRegistry:
    """Declare-once metric namespace with attachable sinks.

    ``counter``/``gauge``/``histogram`` are get-or-create: re-declaring an
    existing name returns the same metric (so two modules can share one
    witness) but a kind mismatch raises — a counter cannot quietly become
    a gauge.  ``emit(kind=...)`` snapshots every metric and fans the
    record out to the attached sinks (see ``repro.obs.sinks``).
    """

    def __init__(self, namespace: str = ""):
        self.namespace = str(namespace)
        self._lock = threading.Lock()
        self._metrics: dict[str, _Metric] = {}
        self._sinks: list = []

    # -- declaration --------------------------------------------------------

    def _declare(self, cls, name, desc, labels, **kw) -> _Metric:
        with self._lock:
            m = self._metrics.get(name)
            if m is not None:
                if not isinstance(m, cls):
                    raise ValueError(
                        f"metric {name!r} already registered as {m.kind}"
                    )
                return m
            m = cls(name, desc, tuple(labels), **kw)
            self._metrics[name] = m
            return m

    def counter(self, name: str, desc: str,
                labels: tuple = ()) -> Counter:
        return self._declare(Counter, name, desc, labels)

    def gauge(self, name: str, desc: str, labels: tuple = ()) -> Gauge:
        return self._declare(Gauge, name, desc, labels)

    def histogram(self, name: str, desc: str, labels: tuple = (), *,
                  buckets=DEFAULT_BUCKETS,
                  max_samples: int = 8192) -> Histogram:
        return self._declare(Histogram, name, desc, labels,
                             buckets=buckets, max_samples=max_samples)

    # -- introspection ------------------------------------------------------

    def metrics(self) -> list:
        with self._lock:
            return list(self._metrics.values())

    def get(self, name: str) -> Optional[_Metric]:
        with self._lock:
            return self._metrics.get(name)

    def describe(self) -> dict:
        """``{name: (kind, description, labelnames)}`` — the one source of
        truth the Prometheus HELP lines and the generated stats-key
        reference table both render from."""
        return {
            m.name: (m.kind, m.desc, m.labelnames) for m in self.metrics()
        }

    def snapshot(self) -> dict:
        """Flat ``{key: value}`` of every handle.  Label-less metrics key
        by bare name; labeled ones by ``name{a=x,b=y}`` (deterministic
        label order = declaration order)."""
        out = {}
        for m in self.metrics():
            for key, h in m.samples():
                if m.labelnames:
                    lbl = ",".join(f"{n}={v}" for n, v in
                                   zip(m.labelnames, key))
                    out[f"{m.name}{{{lbl}}}"] = h.value()
                else:
                    out[m.name] = h.value()
        return out

    # -- sinks --------------------------------------------------------------

    def attach(self, sink) -> None:
        """Attach a sink (anything with ``emit(record)``); ``emit`` fans
        out to every attached sink."""
        with self._lock:
            self._sinks.append(sink)

    @property
    def sinks(self) -> tuple:
        with self._lock:
            return tuple(self._sinks)

    def emit(self, kind: str = "snapshot", extra: Optional[dict] = None,
             ) -> dict:
        """Snapshot every metric into one record and hand it to each
        attached sink.  Returns the record (so callers without sinks can
        still use ``emit`` as 'snapshot with provenance')."""
        record = {
            "kind": str(kind),
            "namespace": self.namespace,
            "t_wall": time.time(),       # provenance only, never a witness
            "metrics": self.snapshot(),
        }
        if extra:
            record.update(extra)
        for sink in self.sinks:
            sink.emit(record)
        return record

    def close(self) -> None:
        for sink in self.sinks:
            close = getattr(sink, "close", None)
            if close is not None:
                close()


_DEFAULT = MetricsRegistry(namespace="repro")


def default_registry() -> MetricsRegistry:
    """The process-wide registry (ad-hoc scripts, single-tenant tools).
    Subsystems that can exist N times per process — ``DetectorPool`` —
    scope their own instance instead."""
    return _DEFAULT
