"""Architecture configs: the 10 assigned public architectures + the paper's
own sensor configs, plus reduced smoke variants of each family.

``get(name)`` returns the full ModelConfig; ``get_smoke(name)`` a reduced
config of the same family for CPU tests; ``SHAPES`` the assigned input-shape
grid.
"""
from __future__ import annotations

import dataclasses
import importlib

from repro.models.common import ModelConfig

ARCHS = [
    "olmoe_1b_7b",
    "deepseek_v3_671b",
    "phi_3_vision_4_2b",
    "whisper_tiny",
    "qwen2_5_3b",
    "granite_20b",
    "qwen2_0_5b",
    "stablelm_3b",
    "mamba2_370m",
    "zamba2_1_2b",
]

# Assigned shape grid: name -> (kind, seq_len, global_batch)
SHAPES = {
    "train_4k": ("train", 4096, 256),
    "prefill_32k": ("prefill", 32768, 32),
    "decode_32k": ("decode", 32768, 128),
    "long_500k": ("decode", 524288, 1),
}

# Archs allowed to run long_500k (sub-quadratic decode); the pure
# full-attention archs skip it (see DESIGN.md §Arch-applicability).
LONG_CONTEXT_OK = {"mamba2_370m", "zamba2_1_2b"}


def canon(name: str) -> str:
    return name.replace("-", "_").replace(".", "_")


def get(name: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{canon(name)}")
    return mod.CONFIG


def get_smoke(name: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{canon(name)}")
    return mod.SMOKE


def cells(include_skipped: bool = False):
    """The 40 (arch x shape) cells; skipped cells flagged."""
    out = []
    for a in ARCHS:
        cfg = get(a)
        for s, (kind, seq, gb) in SHAPES.items():
            skip = None
            if s == "long_500k" and a not in LONG_CONTEXT_OK:
                skip = "full-attention arch: 500k dense decode excluded per brief"
            out.append({"arch": a, "shape": s, "kind": kind, "seq": seq,
                        "batch": gb, "skip": skip})
    return out if include_skipped else [c for c in out if c["skip"] is None]
