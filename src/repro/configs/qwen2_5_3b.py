"""Qwen2.5-3B [arXiv:2407.10671 family; hf]: 36L d2048 16H GQA kv=2,
d_ff 11008, vocab 151936, QKV bias."""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-3b",
    family="dense",
    n_layers=36,
    d_model=2048,
    n_heads=16,
    n_kv=2,
    d_ff=11008,
    vocab=151936,
    qkv_bias=True,
    rope_theta=1000000.0,
)

SMOKE = ModelConfig(
    name="qwen25-smoke",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv=2,
    d_ff=128,
    vocab=256,
    qkv_bias=True,
    loss_chunk=32,
)
