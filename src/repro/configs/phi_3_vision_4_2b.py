"""Phi-3-vision 4.2B [hf:microsoft/Phi-3-vision-128k-instruct]: phi3-mini
backbone 32L d3072 32H (kv=32) d_ff 8192, vocab 32064 + CLIP frontend (STUB:
input_specs provides precomputed patch embeddings; 576 image tokens)."""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="phi-3-vision-4.2b",
    family="vlm",
    n_layers=32,
    d_model=3072,
    n_heads=32,
    n_kv=32,
    d_ff=8192,
    vocab=32064,
    n_img_tokens=576,
    rope_theta=10000.0,
)

SMOKE = ModelConfig(
    name="phi3v-smoke",
    family="vlm",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv=4,
    d_ff=128,
    vocab=256,
    n_img_tokens=16,
    loss_chunk=32,
)
