"""OLMoE-1B-7B [arXiv:2409.02060; hf]: 16L d2048 16H (kv=16) MoE 64e top-8,
expert FF 1024, vocab 50304."""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="olmoe-1b-7b",
    family="moe",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv=16,
    d_ff=1024,            # unused for routed path; experts use d_expert
    vocab=50304,
    n_experts=64,
    top_k=8,
    d_expert=1024,
    rope_theta=10000.0,
)

SMOKE = ModelConfig(
    name="olmoe-smoke",
    family="moe",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv=4,
    d_ff=128,
    vocab=256,
    n_experts=8,
    top_k=2,
    d_expert=128,
    loss_chunk=32,
)
