"""Whisper-tiny [arXiv:2212.04356]: enc-dec, 4L encoder + 4L decoder, d384,
6H, d_ff 1536, vocab 51865; conv frontend STUB (input_specs provides 1500
precomputed frame embeddings).  Decoder max target length 448 — decode-shape
KV caches clamp to it (noted per brief)."""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny",
    family="encdec",
    n_layers=4,
    n_enc_layers=4,
    d_model=384,
    n_heads=6,
    n_kv=6,
    d_ff=1536,
    vocab=51865,
    qkv_bias=True,
    n_audio_frames=1500,
    max_target_len=448,
    tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="whisper-smoke",
    family="encdec",
    n_layers=2,
    n_enc_layers=2,
    d_model=64,
    n_heads=4,
    n_kv=4,
    d_ff=128,
    vocab=256,
    qkv_bias=True,
    n_audio_frames=32,
    max_target_len=32,
    tie_embeddings=True,
    loss_chunk=16,
)
