"""Qwen2-0.5B [arXiv:2407.10671; hf]: 24L d896 14H GQA kv=2, d_ff 4864,
vocab 151936, QKV bias, tied embeddings."""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-0.5b",
    family="dense",
    n_layers=24,
    d_model=896,
    n_heads=14,
    n_kv=2,
    d_ff=4864,
    vocab=151936,
    qkv_bias=True,
    tie_embeddings=True,
    rope_theta=1000000.0,
)

SMOKE = ModelConfig(
    name="qwen2-smoke",
    family="dense",
    n_layers=2,
    d_model=56,
    n_heads=4,
    n_kv=2,
    d_ff=128,
    vocab=256,
    qkv_bias=True,
    tie_embeddings=True,
    loss_chunk=32,
)
