"""StableLM-3B [hf:stabilityai family]: 32L d2560 32H full MHA (kv=32),
d_ff 6912, vocab 50304."""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-3b",
    family="dense",
    n_layers=32,
    d_model=2560,
    n_heads=32,
    n_kv=32,
    d_ff=6912,
    vocab=50304,
    rope_theta=10000.0,
)

SMOKE = ModelConfig(
    name="stablelm-smoke",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv=4,
    d_ff=128,
    vocab=256,
    loss_chunk=32,
)
