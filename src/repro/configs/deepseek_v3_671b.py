"""DeepSeek-V3 671B [arXiv:2412.19437; hf]: 61L d7168, MLA (q_lora 1536,
kv_lora 512, nope 128, rope 64, v 128) 128 heads, MoE 1 shared + 256 routed
top-8 (expert FF 2048), MTP, vocab 129280."""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=128,
    n_kv=128,
    d_ff=2048,
    vocab=129280,
    n_experts=256,
    top_k=8,
    d_expert=2048,
    n_shared_experts=1,
    mla=True,
    q_lora_rank=1536,
    kv_lora_rank=512,
    qk_nope_dim=128,
    qk_rope_dim=64,
    v_head_dim=128,
    mtp=True,
    rope_theta=10000.0,
    remat="full",          # 61 x 7168: remat everything by default
)

SMOKE = ModelConfig(
    name="deepseek-smoke",
    family="moe",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv=4,
    d_ff=96,
    vocab=256,
    n_experts=8,
    top_k=2,
    d_expert=96,
    n_shared_experts=1,
    mla=True,
    q_lora_rank=32,
    kv_lora_rank=16,
    qk_nope_dim=16,
    qk_rope_dim=8,
    v_head_dim=16,
    mtp=True,
    loss_chunk=32,
)
