"""Granite-20B (code) [arXiv:2405.04324; hf]: 52L d6144 48H MQA (kv=1),
d_ff 24576, vocab 49152."""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="granite-20b",
    family="dense",
    n_layers=52,
    d_model=6144,
    n_heads=48,
    n_kv=1,
    d_ff=24576,
    vocab=49152,
    rope_theta=10000.0,
)

SMOKE = ModelConfig(
    name="granite-smoke",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv=1,
    d_ff=128,
    vocab=256,
    loss_chunk=32,
)
