"""Mamba2-370M [arXiv:2405.21060]: 48L d1024 attention-free SSD,
ssm_state 128, expand 2, headdim 64, vocab 50280."""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-370m",
    family="ssm",
    n_layers=48,
    d_model=1024,
    n_heads=0,
    n_kv=0,
    d_ff=0,
    vocab=50280,
    ssm_state=128,
    ssm_expand=2,
    ssm_headdim=64,
    ssm_conv=4,
    ssm_chunk=256,
    tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="mamba2-smoke",
    family="ssm",
    n_layers=2,
    d_model=64,
    n_heads=0,
    n_kv=0,
    d_ff=0,
    vocab=256,
    ssm_state=16,
    ssm_expand=2,
    ssm_headdim=16,
    ssm_conv=4,
    ssm_chunk=16,
    tie_embeddings=True,
    loss_chunk=32,
)
