"""Zamba2-1.2B [arXiv:2411.15242; hf]: 38L Mamba2 backbone d2048
(ssm_state 64) + ONE shared attention+MLP block (32H kv=32, d_ff 8192)
invoked every 6 layers with concat(h, embed) input, vocab 32000.

For the long_500k decode shape the shared attention uses an 8k sliding
window (ring-buffer KV) — noted as a hardware adaptation in DESIGN.md."""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b",
    family="hybrid",
    n_layers=38,
    d_model=2048,
    n_heads=32,
    n_kv=32,
    d_ff=8192,
    vocab=32000,
    ssm_state=64,
    ssm_expand=2,
    ssm_headdim=64,
    ssm_conv=4,
    ssm_chunk=256,
    shared_attn_every=6,
    sliding_window=8192,
)

SMOKE = ModelConfig(
    name="zamba2-smoke",
    family="hybrid",
    n_layers=4,
    d_model=64,
    n_heads=4,
    n_kv=4,
    d_ff=128,
    vocab=256,
    ssm_state=16,
    ssm_expand=2,
    ssm_headdim=16,
    ssm_conv=4,
    ssm_chunk=16,
    shared_attn_every=2,
    sliding_window=64,
    loss_chunk=32,
)
